"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST set the placeholder-device flag before any other import — jax locks the
device count on first init.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, applicable, get_config
from ..configs.registry import ARCH_IDS
from ..models import model as M
from ..models.common import mesh_data_axes, partition_spec_tree
from ..train.optimizer import AdamWCfg
from ..train.train_step import init_train_state, make_train_step
from .mesh import make_production_mesh

RESULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
                "s64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1}
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|u32|s16|u16|s8|u8|"
                       r"pred)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-shape bytes of every collective op in the compiled HLO.

    Convention: per-op logical payload = result shape bytes (ring algorithms
    move ~1x result bytes per chip for all-gather/reduce-scatter and ~2x for
    all-reduce; we report raw result bytes and keep the convention fixed
    across perf iterations so deltas are meaningful).

    XLA's combiner passes merge many small collectives into ONE op with a
    tuple result — `%ar = (f32[128], s32[64]) all-reduce(...)` — so every
    shape in the RESULT (the text between '=' and the op name) is summed.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for cname in _COLLECTIVES:
            tok_plain = f" {cname}("
            tok_start = f" {cname}-start("
            tok = tok_plain if tok_plain in stripped else (
                tok_start if tok_start in stripped else None)
            if tok is None:
                continue
            eq = stripped.find("= ")
            end = stripped.find(tok)
            region = stripped[eq + 2: end] if 0 <= eq < end else stripped
            total = 0
            for m in _SHAPE_RE.finditer(region):
                dt, dims = m.group(1), m.group(2)
                size = 1
                for d in dims.split(","):
                    if d:
                        size *= int(d)
                base = next((v for k, v in _DTYPE_BYTES.items()
                             if dt.startswith(k)), 4)
                total += size * base
            out[cname] += float(total)
            out["count"] += 1
            break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def state_shardings(mesh, state_shapes):
    specs = {
        "params": partition_spec_tree(state_shapes["params"], mesh=mesh),
        "opt": {
            "m": partition_spec_tree(state_shapes["opt"]["m"], mesh=mesh),
            "v": partition_spec_tree(state_shapes["opt"]["v"], mesh=mesh),
            "step": P(),
        },
    }
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_shardings(mesh, batch_shapes):
    da = mesh_data_axes(mesh)

    def spec(path, leaf):
        return NamedSharding(mesh, P(da, *([None] * (len(leaf.shape) - 1))))

    return jax.tree_util.tree_map_with_path(spec, batch_shapes)


def cache_shardings(mesh, cache_shapes, batch: int):
    """Decode-cache shardings.  batch >= dp: shard batch over data axes;
    batch == 1 (long_500k): shard the sequence dim over data axes instead
    (sequence-parallel decode).  Head/state dims shard over "model"."""
    da = mesh_data_axes(mesh)
    dp = 1
    for a in da:
        dp *= mesh.shape[a]

    tp = mesh.shape["model"]

    def spec(path, leaf):
        keys = "/".join(str(getattr(k, "key", k)) for k in path)
        shape = leaf.shape
        stacked = "stack" in keys          # leading layer-stack axis
        dims = shape[1:] if stacked else shape
        axes: list = [None] * len(dims)
        batch_ok = dims[0] >= dp and dims[0] % dp == 0
        if "kv" in keys and len(dims) == 4:
            # [B, S, Hk, hd]: batch over data if it divides, else
            # sequence-parallel decode (long_500k, batch 1)
            if batch_ok:
                axes[0] = da
            elif dims[1] % dp == 0:
                axes[1] = da
            if dims[2] % tp == 0:
                axes[2] = "model"          # kv heads over TP
            elif axes[1] is None and dims[1] % tp == 0:
                # kv heads indivisible by TP (GQA kv<=8 vs model=16):
                # sequence-shard the cache over "model" instead — decode
                # attention psums over the model axis (sequence-parallel)
                axes[1] = "model"
        elif "ssm" in keys and len(dims) == 4:
            # [B, H, N, P]: heads over TP, batch over data if divisible
            if batch_ok:
                axes[0] = da
            if dims[1] % tp == 0:
                axes[1] = "model"
        elif "conv" in keys and len(dims) == 3:
            # [B, W-1, C]: channels over TP, batch over data if divisible
            if batch_ok:
                axes[0] = da
            if dims[2] % tp == 0:
                axes[2] = "model"
        if stacked:
            axes = [None] + axes
        return NamedSharding(mesh, P(*axes))

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


ATTN_FSDP_OVERRIDES = {
    # hillclimb: heads-indivisible TP (yi-34b: 56 heads vs model=16) —
    # keep attention weights FSDP-only so activations never shard on heads
    r"attn.*/wq$": ("data", None),
    r"attn.*/wk$": ("data", None),
    r"attn.*/wv$": ("data", None),
    r"attn.*/wo$": (None, "data"),
}


def _compile_cell(cfg, cell, mesh, spec_overrides=None, step_variant=None):
    """Lower + compile one configuration; returns (lowered, compiled)."""
    import re as _re
    from ..models import common as _common

    if spec_overrides:
        orig = _common.spec_for_path

        def patched(path, ndim, ep=False):
            for pat, axes in spec_overrides.items():
                if _re.search(pat, path):
                    spec_axes = list(axes) + [None] * (ndim - len(axes))
                    if "stack" in path:
                        spec_axes = [None] + spec_axes[: ndim - 1]
                    return P(*spec_axes[:ndim])
            return orig(path, ndim, ep)

        _common.spec_for_path = patched
    try:
        return _compile_cell_inner(cfg, cell, mesh, step_variant)
    finally:
        if spec_overrides:
            _common.spec_for_path = orig


def _compile_cell_inner(cfg, cell, mesh, step_variant=None):
    if cell.kind == "train":
        opt_cfg = AdamWCfg()
        microbatches = int(os.environ.get("DRYRUN_MICROBATCHES", "1"))
        if step_variant == "compressed_dp":
            from ..train.compression import make_compressed_dp_step
            step = make_compressed_dp_step(cfg, mesh, opt_cfg)
            state_shapes = jax.eval_shape(
                lambda: init_train_state(cfg, jax.random.PRNGKey(0)))
            # TernGrad regime: params+opt replicated, batch over data axes
            rep = jax.tree.map(lambda _: NamedSharding(mesh, P()),
                               state_shapes)
            in_shard = (rep,
                        batch_shardings(mesh, M.input_specs(cfg, cell)))
            jitted = jax.jit(step, in_shardings=in_shard)
            return jitted.lower(state_shapes, M.input_specs(cfg, cell)), None
        step = make_train_step(cfg, mesh, opt_cfg,
                               microbatches=microbatches)
        state_shapes = jax.eval_shape(
            lambda: init_train_state(cfg, jax.random.PRNGKey(0)))
        in_shard = (state_shardings(mesh, state_shapes),
                    batch_shardings(mesh, M.input_specs(cfg, cell)))
        jitted = jax.jit(step, in_shardings=in_shard)
        lowered = jitted.lower(state_shapes, M.input_specs(cfg, cell))
    elif cell.kind == "prefill":
        def prefill(params, batch):
            return M.forward(cfg, params, batch, mesh)
        params_shapes = jax.eval_shape(
            lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
        pspec = jax.tree.map(lambda s: NamedSharding(mesh, s),
                             partition_spec_tree(params_shapes, mesh=mesh),
                             is_leaf=lambda x: isinstance(x, P))
        in_shard = (pspec, batch_shardings(mesh, M.input_specs(cfg, cell)))
        jitted = jax.jit(prefill, in_shardings=in_shard)
        lowered = jitted.lower(params_shapes, M.input_specs(cfg, cell))
    else:                                   # decode
        def serve_step(params, cache, tokens, pos):
            return M.decode_step(cfg, params, cache, tokens, pos, mesh)

        def make_params():
            prm = M.init_params(cfg, jax.random.PRNGKey(0))
            if os.environ.get("DRYRUN_TERNARY_PACKED"):
                from ..models.quant import quantize_model_params
                prm = quantize_model_params(prm)
            return prm

        params_shapes = jax.eval_shape(make_params)
        pspec = jax.tree.map(lambda s: NamedSharding(mesh, s),
                             partition_spec_tree(params_shapes, mesh=mesh),
                             is_leaf=lambda x: isinstance(x, P))
        specs = M.input_specs(cfg, cell)
        da = mesh_data_axes(mesh)
        dp = 1
        for a in da:
            dp *= mesh.shape[a]
        tok_spec = NamedSharding(
            mesh, P(da) if cell.global_batch % dp == 0
            and cell.global_batch >= dp else P())
        in_shard = (pspec,
                    cache_shardings(mesh, specs["cache"], cell.global_batch),
                    tok_spec, NamedSharding(mesh, P()))
        jitted = jax.jit(serve_step, in_shardings=in_shard)
        lowered = jitted.lower(params_shapes, specs["cache"],
                               specs["tokens"], specs["pos"])
    return lowered, lowered.compile()


def _costs_of(compiled) -> dict:
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", -1)),
            "bytes_accessed": float(cost.get("bytes accessed", -1)),
            "collectives": coll}


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               remat: str | None = None, moe_parallelism: str | None = None,
               extra_tag: str = "", probes: bool = True,
               attn_fsdp: bool = False, step_variant: str | None = None,
               capacity_factor: float | None = None,
               attn_batch_split: bool = False,
               n_heads: int | None = None,
               ternary_packed: bool = False) -> dict:
    """Lower+compile one cell; return the record (incl. roofline inputs).

    Cost accounting: XLA's cost analysis counts while-loop (lax.scan) bodies
    ONCE, so the full scanned compile underreports flops/bytes/collectives.
    We therefore (a) keep the full compile as the REQUIRED dry-run artifact
    (memory analysis, compile success, scanned collective structure) and
    (b) run two small UNROLLED probe compiles at 1 and 2 super-blocks
    (probe_unroll=True: dense/unrolled attention + SSD + layer loop) and
    extrapolate linearly to the full depth:
        cost(L) = c1 + (c2 - c1) * (L/period - 1).
    """
    cell = SHAPES[shape_name]
    cfg = get_config(arch)
    if remat:
        cfg = cfg.with_(remat=remat)
    if moe_parallelism and cfg.moe:
        cfg = cfg.with_(moe=cfg.moe.__class__(
            **{**cfg.moe.__dict__, "parallelism": moe_parallelism}))
    if capacity_factor and cfg.moe:
        cfg = cfg.with_(moe=cfg.moe.__class__(
            **{**cfg.moe.__dict__, "capacity_factor": capacity_factor}))
    if attn_batch_split:
        cfg = cfg.with_(attn_batch_split=True)
    if n_heads:
        cfg = cfg.with_(n_heads=n_heads)
    if ternary_packed:
        os.environ["DRYRUN_TERNARY_PACKED"] = "1"
    else:
        os.environ.pop("DRYRUN_TERNARY_PACKED", None)
    overrides = ATTN_FSDP_OVERRIDES if attn_fsdp else None
    runs, reason = applicable(cfg, cell)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "remat": cfg.remat, "tag": extra_tag,
           "params_total": cfg.n_params, "params_active": cfg.n_active_params}
    if not runs:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    with mesh:
        # ---- full compile: the dry-run artifact -----------------------------
        t0 = time.time()
        lowered, compiled = _compile_cell(cfg, cell, mesh,
                                          spec_overrides=overrides,
                                          step_variant=step_variant)
        if compiled is None:
            compiled = lowered.compile()
        t_full = time.time() - t0
        mem = compiled.memory_analysis()
        raw = _costs_of(compiled)
        rec.update({
            "status": "ok", "parser": "tuple-aware-v2",
            "compile_s": round(t_full, 2),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes",
                                          None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            },
            "raw_scanned": raw,
        })
        del lowered, compiled

        # ---- probe compiles: exact per-layer cost ---------------------------
        if probes:
            period = cfg.pattern_period
            units = cfg.n_layers / period

            def probe_cfg(u):
                kw = {"n_layers": period * u, "probe_unroll": True}
                if cfg.enc_layers:
                    kw["enc_layers"] = u
                return cfg.with_(**kw)

            t0 = time.time()
            # probe0: zero layers (embed + head + loss + optimizer only);
            # probe1: one super-block, fully unrolled.  cost(L) =
            # c0 + (c1 - c0) * L/period — exact for identical layers.
            lw0, comp0 = _compile_cell(probe_cfg(0), cell, mesh,
                                       spec_overrides=overrides,
                                       step_variant=step_variant)
            comp0 = comp0 or lw0.compile()
            c0 = _costs_of(comp0)
            del comp0
            lw1, comp1 = _compile_cell(probe_cfg(1), cell, mesh,
                                       spec_overrides=overrides,
                                       step_variant=step_variant)
            comp1 = comp1 or lw1.compile()
            c1 = _costs_of(comp1)
            del comp1
            rec["probe_s"] = round(time.time() - t0, 2)

            def extrap(a, b):
                return a + (b - a) * units

            # enc-dec: enc scales with probes but is fixed (=n_layers) in
            # the full model, and n_layers == enc_layers for seamless, so
            # the per-unit delta (1 dec + 1 enc layer) extrapolates exactly.
            coll = {k: extrap(c0["collectives"][k], c1["collectives"][k])
                    for k in c0["collectives"]}
            rec.update({
                "flops": extrap(c0["flops"], c1["flops"]),
                "bytes_accessed": extrap(c0["bytes_accessed"],
                                         c1["bytes_accessed"]),
                "collectives": coll,
                "probe_costs": {"c0": c0, "c1": c1},
            })
        else:
            rec.update({"flops": raw["flops"],
                        "bytes_accessed": raw["bytes_accessed"],
                        "collectives": raw["collectives"]})
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + (None,),
                    nargs="?")
    ap.add_argument("--shape", default=None, choices=tuple(SHAPES) + (None,))
    ap.add_argument("--mesh", default="pod", choices=("pod", "multipod",
                                                      "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default=None)
    ap.add_argument("--moe-parallelism", default=None)
    ap.add_argument("--attn-fsdp", action="store_true")
    ap.add_argument("--attn-batch-split", action="store_true")
    ap.add_argument("--step-variant", default=None)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--ternary-packed", action="store_true",
                    help="serve-path packed 2-bit MLP weights (paper "
                         "technique; decode/prefill cells)")
    ap.add_argument("--n-heads", type=int, default=None,
                    help="pad/override query head count (e.g. yi-34b 56->64 "
                         "for divisible TP; zero-padded wo rows keep the "
                         "function identical)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out-dir", default=RESULT_DIR)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    archs = ARCH_IDS if (args.all or args.arch is None) else (args.arch,)
    shapes = tuple(SHAPES) if (args.all or args.shape is None) \
        else (args.shape,)
    meshes = {"pod": (False,), "multipod": (True,),
              "both": (False, True)}[args.mesh]

    cells = [(arch, shape, mp) for mp in meshes for arch in archs
             for shape in shapes]
    for arch, shape, mp in cells:
            if True:
                mesh_name = "2x16x16" if mp else "16x16"
                tagpart = f"_{args.tag}" if args.tag else ""
                fname = os.path.join(
                    args.out_dir, f"{arch}_{shape}_{mesh_name}{tagpart}.json")
                if os.path.exists(fname):
                    print(f"[skip] {fname} exists")
                    continue
                print(f"[dryrun] {arch} x {shape} x {mesh_name} ...",
                      flush=True)
                try:
                    rec = lower_cell(arch, shape, mp, remat=args.remat,
                                     moe_parallelism=args.moe_parallelism,
                                     extra_tag=args.tag,
                                     probes=not mp,  # roofline: single-pod
                                     attn_fsdp=args.attn_fsdp,
                                     step_variant=args.step_variant,
                                     capacity_factor=args.capacity_factor,
                                     attn_batch_split=args.attn_batch_split,
                                     n_heads=args.n_heads,
                                     ternary_packed=args.ternary_packed)
                except Exception as e:                 # noqa: BLE001
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": str(e),
                           "traceback": traceback.format_exc()[-4000:]}
                with open(fname, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    extra = (f" flops={rec['flops']:.3e}"
                             f" coll={rec['collectives']['total']:.3e}B"
                             f" compile={rec['compile_s']}s")
                print(f"[done] {arch} x {shape} x {mesh_name}: "
                      f"{status}{extra}", flush=True)


if __name__ == "__main__":
    main()
