"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``."""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, get_smoke_config
from ..configs.registry import ARCH_IDS
from ..models import model as M
from ..serve import Engine, ServeCfg
from .mesh import make_elastic_mesh, make_smoke_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_smoke_mesh() if args.smoke and len(jax.devices()) == 1 \
        else make_elastic_mesh()
    with mesh:
        params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, mesh,
                    ServeCfg(max_len=args.max_len,
                             temperature=args.temperature))
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)
    t0 = time.perf_counter()
    out = engine.generate(prompts, args.new_tokens)
    dt = time.perf_counter() - t0
    toks = args.batch * args.new_tokens
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s batched)")
    print("sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
