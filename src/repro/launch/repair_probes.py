"""Re-extract probe costs for existing single-pod dry-run records.

Used after the collective-parser fix (tuple-result combined collectives):
reruns ONLY the cheap probe compiles (0 and 1 super-blocks) per record and
rewrites flops / bytes / collectives, keeping the original full-compile
memory analysis and timings.
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

# ruff: noqa: E402
import json
import sys
import time

import jax

from ..configs import SHAPES, get_config
from .dryrun import RESULT_DIR, _compile_cell, _costs_of
from .mesh import make_production_mesh


def repair(fn: str) -> None:
    path = os.path.join(RESULT_DIR, fn)
    rec = json.load(open(path))
    if rec.get("status") != "ok" or rec.get("mesh") != "16x16":
        return
    cfg = get_config(rec["arch"])
    if rec.get("remat") and rec["remat"] != cfg.remat:
        cfg = cfg.with_(remat=rec["remat"])
    cell = SHAPES[rec["shape"]]
    period = cfg.pattern_period
    units = cfg.n_layers / period
    mesh = make_production_mesh(multi_pod=False)
    t0 = time.time()
    with mesh:
        def probe_cfg(u):
            kw = {"n_layers": period * u, "probe_unroll": True}
            if cfg.enc_layers:
                kw["enc_layers"] = u
            return cfg.with_(**kw)

        lw0, c = _compile_cell(probe_cfg(0), cell, mesh)
        c0 = _costs_of(c or lw0.compile())
        lw1, c = _compile_cell(probe_cfg(1), cell, mesh)
        c1 = _costs_of(c or lw1.compile())

    def extrap(a, b):
        return a + (b - a) * units

    rec["collectives"] = {k: extrap(c0["collectives"][k],
                                    c1["collectives"][k])
                          for k in c0["collectives"]}
    rec["flops"] = extrap(c0["flops"], c1["flops"])
    rec["bytes_accessed"] = extrap(c0["bytes_accessed"],
                                   c1["bytes_accessed"])
    rec["probe_costs"] = {"c0": c0, "c1": c1}
    rec["probe_s"] = round(time.time() - t0, 2)
    rec["parser"] = "tuple-aware-v2"
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[repaired] {fn} coll={rec['collectives']['total']:.3e}",
          flush=True)


def main():
    only = sys.argv[1] if len(sys.argv) > 1 else ""
    for fn in sorted(os.listdir(RESULT_DIR)):
        if not fn.endswith(".json") or only not in fn:
            continue
        try:
            rec = json.load(open(os.path.join(RESULT_DIR, fn)))
            if rec.get("parser") == "tuple-aware-v2":
                print(f"[skip] {fn}")
                continue
            repair(fn)
        except Exception as e:              # noqa: BLE001
            print(f"[fail] {fn}: {e}", flush=True)


if __name__ == "__main__":
    main()
