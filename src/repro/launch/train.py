"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Production path: builds the (elastic) mesh from available devices, shards
state per the partition rules, resumes from the latest checkpoint, runs the
fault-tolerant loop.  ``--smoke`` selects the reduced config for CPU runs.
"""
from __future__ import annotations

import argparse
import logging

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_config, get_smoke_config
from ..configs.registry import ARCH_IDS
from ..data import DataCfg, TokenSource
from ..models.common import mesh_data_axes, partition_spec_tree
from ..train.compression import make_compressed_dp_step
from ..train.optimizer import AdamWCfg
from ..train.runtime import RunCfg, train_loop
from ..train.train_step import init_train_state, make_train_step
from .mesh import make_elastic_mesh, make_smoke_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compressed-dp", action="store_true",
                    help="pure-DP + TernGrad ternary gradient all-reduce")
    ap.add_argument("--data-path", default=None)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.remat:
        cfg = cfg.with_(remat=args.remat)

    mesh = make_smoke_mesh() if args.smoke and len(jax.devices()) == 1 \
        else make_elastic_mesh()
    opt_cfg = AdamWCfg(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(1, args.steps // 20))
    source = TokenSource(
        DataCfg(vocab=cfg.vocab, global_batch=args.batch, seq_len=args.seq,
                path=args.data_path),
        process_index=jax.process_index(),
        process_count=jax.process_count())

    with mesh:
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        pspecs = {
            "params": partition_spec_tree(state["params"], mesh=mesh),
            "opt": {"m": partition_spec_tree(state["opt"]["m"], mesh=mesh),
                    "v": partition_spec_tree(state["opt"]["v"], mesh=mesh),
                    "step": P()},
        }
        shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                                 is_leaf=lambda x: isinstance(x, P))
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
        if args.compressed_dp:
            step = jax.jit(make_compressed_dp_step(cfg, mesh, opt_cfg))
        else:
            step = jax.jit(make_train_step(cfg, mesh, opt_cfg,
                                           microbatches=args.microbatches))
        run = RunCfg(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every)
        state, summary = train_loop(run, state, step, source,
                                    state_shardings=shardings)
    print(f"done: steps={summary['final_step']} "
          f"loss {summary['loss_first']:.4f} -> {summary['loss_last']:.4f} "
          f"stragglers={summary['stragglers']}")


if __name__ == "__main__":
    main()
