"""Assemble the data-driven sections of EXPERIMENTS.md from the artifacts.

Usage: PYTHONPATH=src python -m repro.launch.report > experiments/report.md
"""
from __future__ import annotations

import json
import os

from ..configs.shapes import SHAPES
from .roofline import DIR, analyze

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh: str, tag: str = "") -> dict:
    out = {}
    for fn in sorted(os.listdir(DIR)):
        if not fn.endswith(".json"):
            continue
        r = json.load(open(os.path.join(DIR, fn)))
        if r.get("mesh") != mesh or r.get("tag", "") != tag:
            continue
        out[(r["arch"], r["shape"])] = analyze(
            r, 512 if mesh == "2x16x16" else 256, SHAPES)
    return out


def fmt_b(x) -> str:
    if x is None:
        return "-"
    return f"{x/1e9:.2f}"


def dryrun_table() -> str:
    single = load("16x16")
    multi = load("2x16x16")
    lines = ["| arch | shape | 16x16 (256) | 2x16x16 (512) | per-chip temp GB"
             " | per-chip args GB | HLO GFLOPs/chip | collective GB/chip |",
             "|---|---|---|---|---|---|---|---|"]
    archs = sorted({a for a, _ in set(single) | set(multi)})
    for a in archs:
        for sh in ORDER:
            s = single.get((a, sh))
            m = multi.get((a, sh))
            if s is None and m is None:
                continue
            r = s or m

            def st(x):
                if x is None:
                    return "missing"
                if x["status"] == "skipped":
                    return "skip (full-attn)"
                return "OK" if x["status"] == "ok" else x["status"]

            if r["status"] != "ok":
                lines.append(f"| {a} | {sh} | {st(s)} | {st(m)} | - | - |"
                             f" - | - |")
                continue
            mem = r.get("memory", {})
            lines.append(
                f"| {a} | {sh} | {st(s)} | {st(m)} "
                f"| {fmt_b(mem.get('temp_bytes'))} "
                f"| {fmt_b(mem.get('argument_bytes'))} "
                f"| {r.get('flops', 0)/1e9:.0f} "
                f"| {r.get('collectives', {}).get('total', 0)/1e9:.2f} |")
    return "\n".join(lines)


def roofline_table() -> str:
    single = load("16x16")
    lines = ["| arch | shape | compute s | memory s | collective s |"
             " dominant | MODEL/HLO | roofline frac |",
             "|---|---|---|---|---|---|---|---|"]
    for (a, sh) in sorted(single):
        r = single[(a, sh)]
        if r["status"] != "ok":
            continue
        t = r["terms"]
        lines.append(
            f"| {a} | {sh} | {t['compute_s']:.4f} | {t['memory_s']:.4f} "
            f"| {t['collective_s']:.4f} | {r['dominant'].replace('_s','')} "
            f"| {(r['model_to_hlo_flops'] or 0):.3f} "
            f"| {(r['roofline_fraction'] or 0):.4f} |")
    return "\n".join(lines)


def perf_table() -> str:
    rows = []
    for fn in sorted(os.listdir(DIR)):
        if not fn.endswith(".json"):
            continue
        r = json.load(open(os.path.join(DIR, fn)))
        if r.get("mesh") != "16x16" or r.get("status") != "ok":
            continue
        tag = r.get("tag", "") or "baseline"
        key = (r["arch"], r["shape"])
        if key in {("qwen3-0.6b", "train_4k"), ("yi-34b", "train_4k"),
                   ("qwen3-moe-30b-a3b", "train_4k")}:
            a = analyze(r, 256, SHAPES)
            rows.append((r["arch"], tag, a))
    lines = ["| arch | variant | compute s | memory s | collective s |"
             " MODEL/HLO | temp GB |",
             "|---|---|---|---|---|---|---|"]
    for arch, tag, a in rows:
        t = a["terms"]
        mem = a.get("memory", {})
        lines.append(
            f"| {arch} | {tag} | {t['compute_s']:.4f} | {t['memory_s']:.3f} "
            f"| {t['collective_s']:.4f} | {(a['model_to_hlo_flops'] or 0):.3f}"
            f" | {fmt_b(mem.get('temp_bytes'))} |")
    return "\n".join(lines)


def main():
    print("## §Dry-run (both meshes)\n")
    print(dryrun_table())
    print("\n## §Roofline (single pod, 256 chips)\n")
    print(roofline_table())
    print("\n## §Perf variants (hillclimb cells)\n")
    print(perf_table())


if __name__ == "__main__":
    main()
